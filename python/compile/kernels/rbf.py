"""L1 Pallas kernel: tiled RBF (Gaussian) kernel block.

Computes K[i, j] = exp(-gamma * ||x_i - b_j||^2) for a tile of rows X[T, d]
against a basis block Xb[B, d].

This is the compute hot spot of every solver in the paper (Tyree et al.
2014): SMO spends its time on kernel rows, SP-SVM on kernel columns for
candidate scoring and basis re-optimization. The paper offloads it to
CUBLAS/MKL; here it is a Pallas kernel AOT-lowered into the same HLO module
as the surrounding JAX graph.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the squared distance is expanded as ||x||^2 + ||b||^2 - 2 x.b^T so the
    dominant term is a single MXU-shaped matmul (jnp.dot with
    preferred_element_type=f32);
  * the grid tiles rows of X so each step's working set (X tile, full Xb,
    K tile) fits in a VMEM-sized budget;
  * lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
    custom-calls, so the kernel lowers to plain HLO (while-loop over grid)
    and runs anywhere. Real-TPU numbers are estimated in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of X processed per grid step. 128 keeps the MXU-shaped dot at a
# systolic-array-friendly (128 x d) x (d x B) and the VMEM working set small.
ROW_BLOCK = 128


def _rbf_kernel_body(x_ref, xb_ref, g_ref, o_ref):
    """One grid step: K tile for ROW_BLOCK rows of X against all of Xb."""
    xs = x_ref[...]  # [ROW_BLOCK, d]
    bs = xb_ref[...]  # [B, d]
    # ||x||^2 + ||b||^2 - 2 x.b^T  (the dot is the MXU term)
    xsq = jnp.sum(xs * xs, axis=1, keepdims=True)  # [ROW_BLOCK, 1]
    bsq = jnp.sum(bs * bs, axis=1)[None, :]  # [1, B]
    cross = jnp.dot(xs, bs.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xsq + bsq - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-g_ref[0] * d2)


@functools.partial(jax.jit, static_argnames=())
def rbf_block(x, xb, gamma):
    """K[T, B] = exp(-gamma ||x_i - b_j||^2).

    Args:
      x: [T, d] row tile (T a multiple of ROW_BLOCK).
      xb: [B, d] basis block.
      gamma: [1] inverse kernel width.
    """
    t, d = x.shape
    b = xb.shape[0]
    assert t % ROW_BLOCK == 0, f"T={t} must be a multiple of {ROW_BLOCK}"
    grid = (t // ROW_BLOCK,)
    return pl.pallas_call(
        _rbf_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.float32),
        interpret=True,
    )(x, xb, gamma)


def vmem_bytes(t_block: int, d: int, b: int) -> int:
    """Estimated VMEM working set of one grid step (f32)."""
    return 4 * (t_block * d + b * d + t_block * b + 1)


def mxu_flops(t: int, d: int, b: int) -> int:
    """MXU-eligible flops of the cross-term matmul for a [T,d]x[d,B] tile."""
    return 2 * t * d * b
