"""Pure-jnp oracles for the L1 Pallas kernels and L2 graphs.

Every artifact op has a reference here; pytest asserts allclose between the
Pallas/graph implementation and these. These are also the ground truth the
Rust CPU engines are tested against (mirrored in rust/src/engine.rs tests).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def rbf_block(x, xb, gamma):
    """K[T, B] = exp(-gamma ||x_i - b_j||^2)."""
    d2 = (
        jnp.sum(x * x, axis=1, keepdims=True)
        + jnp.sum(xb * xb, axis=1)[None, :]
        - 2.0 * x @ xb.T
    )
    return jnp.exp(-gamma[0] * jnp.maximum(d2, 0.0))


def hinge_stats(k, y, m, beta, c):
    """Squared-hinge tile statistics (see kernels/hinge.py)."""
    f = k @ beta
    hinge = jnp.maximum(0.0, 1.0 - y * f)
    active = jnp.where(hinge > 0.0, 1.0, 0.0) * m
    w = active * y * hinge
    g = -2.0 * c[0] * (w @ k)
    ka = k * active[:, None]
    h = 2.0 * c[0] * ka.T @ ka
    loss = c[0] * jnp.sum(active * hinge * hinge)
    nerr = jnp.sum(m * jnp.where(y * f <= 0.0, 1.0, 0.0))
    return g, h, jnp.reshape(loss, (1,)), jnp.reshape(nerr, (1,))


def cg_solve(h, g, bmask, reg, iters=64):
    """Masked damped CG solve: (H_mm + reg I) delta = g, delta on mask."""
    bm = np.asarray(bmask)
    hm = np.asarray(h) * np.outer(bm, bm)
    hm = hm + np.diag(np.asarray(reg)[0] * bm + (1.0 - bm))
    b = np.asarray(g) * bm
    x = np.zeros_like(b)
    r = b.copy()
    p = r.copy()
    rs = float(r @ r)
    for _ in range(iters):
        ap = hm @ p
        alpha = rs / max(float(p @ ap), 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = float(r @ r)
        if rs_new < 1e-20:
            break
        p = r + (rs_new / max(rs, 1e-30)) * p
        rs = rs_new
    return x * bm


def score_tile(kc, r, a):
    """Basis-candidate scoring accumulators.

    gc[j] = sum_i r_i Kc[i, j]      (r = a_i * y_i * hinge_i residuals)
    hc[j] = sum_i a_i Kc[i, j]^2
    """
    gc = r @ kc
    hc = a @ (kc * kc)
    return gc, hc


def predict_block(k, beta):
    """Margins f[T] = K beta (bias folded into beta[0] / ones column)."""
    return k @ beta
