"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal)."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf, hinge, ref

RNG = np.random.default_rng(0)


def randn(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


class TestRbfBlock:
    @pytest.mark.parametrize("t,d,b", [(128, 8, 16), (256, 64, 64),
                                       (128, 123, 37), (384, 54, 128)])
    def test_matches_ref(self, t, d, b):
        x, xb, g = randn(t, d), randn(b, d), np.array([0.5], np.float32)
        out = rbf.rbf_block(x, xb, g)
        expect = ref.rbf_block(x, xb, g)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)

    def test_self_kernel_diag_is_one(self):
        x = randn(128, 10)
        k = rbf.rbf_block(x, x[:64], np.array([1.3], np.float32))
        np.testing.assert_allclose(np.diag(np.asarray(k)[:64]), 1.0, atol=1e-5)

    def test_gamma_zero_gives_ones(self):
        k = rbf.rbf_block(randn(128, 4), randn(8, 4), np.zeros(1, np.float32))
        np.testing.assert_allclose(k, 1.0, atol=1e-6)

    def test_values_in_unit_interval(self):
        k = np.asarray(rbf.rbf_block(randn(256, 33), randn(65, 33),
                                     np.array([2.0], np.float32)))
        assert k.min() >= 0.0 and k.max() <= 1.0 + 1e-6

    def test_symmetry_under_swap(self):
        x = randn(128, 12)
        g = np.array([0.7], np.float32)
        k1 = np.asarray(rbf.rbf_block(x, x, g))
        np.testing.assert_allclose(k1, k1.T, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        t_blocks=st.integers(1, 3),
        d=st.integers(1, 96),
        b=st.integers(1, 96),
        gamma=st.floats(0.0, 4.0),
    )
    def test_hypothesis_shape_sweep(self, t_blocks, d, b, gamma):
        t = 128 * t_blocks
        rng = np.random.default_rng(d * 1000 + b)
        x = rng.standard_normal((t, d)).astype(np.float32)
        xb = rng.standard_normal((b, d)).astype(np.float32)
        g = np.array([gamma], np.float32)
        np.testing.assert_allclose(
            rbf.rbf_block(x, xb, g), ref.rbf_block(x, xb, g),
            rtol=1e-4, atol=1e-5)

    def test_vmem_budget_worst_bucket(self):
        # DESIGN.md §Hardware-Adaptation: worst bucket fits a 16MB VMEM.
        assert rbf.vmem_bytes(rbf.ROW_BLOCK, 2048, 512) < 16 * 2 ** 20


class TestHingeStats:
    def _case(self, t, b, seed=1):
        rng = np.random.default_rng(seed)
        k = rng.uniform(0, 1, (t, b)).astype(np.float32)
        k[:, 0] = 1.0  # bias column
        y = rng.choice([-1.0, 1.0], t).astype(np.float32)
        m = (rng.uniform(0, 1, t) > 0.2).astype(np.float32)
        beta = rng.standard_normal(b).astype(np.float32) * 0.1
        c = np.array([3.0], np.float32)
        return k, y, m, beta, c

    @pytest.mark.parametrize("t,b", [(128, 16), (256, 64), (384, 128)])
    def test_matches_ref(self, t, b):
        args = self._case(t, b)
        g, h, loss, nerr = hinge.hinge_stats(*args)
        eg, eh, el, en = ref.hinge_stats(*args)
        np.testing.assert_allclose(g, eg, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h, eh, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(loss, el, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(nerr, en, atol=1e-5)

    def test_gram_is_psd(self):
        args = self._case(256, 32, seed=7)
        _, h, _, _ = hinge.hinge_stats(*args)
        evals = np.linalg.eigvalsh(np.asarray(h, dtype=np.float64))
        assert evals.min() > -1e-3

    def test_masked_rows_do_not_contribute(self):
        k, y, m, beta, c = self._case(128, 16, seed=3)
        m0 = np.zeros_like(m)
        g, h, loss, nerr = hinge.hinge_stats(k, y, m0, beta, c)
        np.testing.assert_allclose(g, 0.0, atol=1e-6)
        np.testing.assert_allclose(h, 0.0, atol=1e-6)
        assert float(loss[0]) == 0.0 and float(nerr[0]) == 0.0

    def test_zero_beta_all_rows_active(self):
        k, y, m, _, c = self._case(128, 16, seed=4)
        beta = np.zeros(16, np.float32)
        _, _, loss, nerr = hinge.hinge_stats(k, y, m, beta, c)
        # f=0 -> hinge=1 for every valid row, and every row counts as error.
        assert float(loss[0]) == pytest.approx(float(c[0]) * m.sum(), rel=1e-5)
        assert float(nerr[0]) == pytest.approx(m.sum())

    def test_accumulates_across_grid_steps(self):
        # result over 3 row-blocks == sum of per-block results
        k, y, m, beta, c = self._case(384, 32, seed=5)
        g, h, loss, nerr = hinge.hinge_stats(k, y, m, beta, c)
        gs = np.zeros(32, np.float32)
        ls = 0.0
        for i in range(3):
            sl = slice(128 * i, 128 * (i + 1))
            gi, _, li, _ = hinge.hinge_stats(k[sl], y[sl], m[sl], beta, c)
            gs += np.asarray(gi)
            ls += float(li[0])
        np.testing.assert_allclose(g, gs, rtol=1e-4, atol=1e-4)
        assert float(loss[0]) == pytest.approx(ls, rel=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(t_blocks=st.integers(1, 3), b=st.integers(2, 48),
           cval=st.floats(0.1, 100.0), seed=st.integers(0, 10 ** 6))
    def test_hypothesis_sweep(self, t_blocks, b, cval, seed):
        rng = np.random.default_rng(seed)
        t = 128 * t_blocks
        k = rng.uniform(0, 1, (t, b)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], t).astype(np.float32)
        m = (rng.uniform(0, 1, t) > 0.5).astype(np.float32)
        beta = (rng.standard_normal(b) * 0.2).astype(np.float32)
        c = np.array([cval], np.float32)
        g, h, loss, nerr = hinge.hinge_stats(k, y, m, beta, c)
        eg, eh, el, en = ref.hinge_stats(k, y, m, beta, c)
        np.testing.assert_allclose(g, eg, rtol=1e-3, atol=1e-3 * cval)
        np.testing.assert_allclose(h, eh, rtol=1e-3, atol=1e-3 * cval)
        np.testing.assert_allclose(loss, el, rtol=1e-3, atol=1e-3 * cval)
        np.testing.assert_allclose(nerr, en, atol=1e-4)
