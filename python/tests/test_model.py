"""L2 graph ops vs oracles, and end-to-end graph composition."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(42)


def randn(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


class TestCgSolve:
    def _spd(self, b, occupied, seed=0):
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((b, b)).astype(np.float32)
        h = a @ a.T / b + 0.5 * np.eye(b, dtype=np.float32)
        bm = np.zeros(b, np.float32)
        bm[:occupied] = 1.0
        g = rng.standard_normal(b).astype(np.float32)
        reg = np.array([1e-3], np.float32)
        return h, g, bm, reg

    @pytest.mark.parametrize("b,occ", [(16, 16), (32, 17), (64, 3), (64, 64)])
    def test_solves_masked_system(self, b, occ):
        h, g, bm, reg = self._spd(b, occ, seed=b + occ)
        (x,) = model.cg_solve(h, g, bm, reg)
        x = np.asarray(x, np.float64)
        hm = (h * np.outer(bm, bm) + np.diag(reg[0] * bm + (1 - bm)))
        resid = hm @ x - g * bm
        assert np.linalg.norm(resid) < 1e-3 * max(1.0, np.linalg.norm(g))

    def test_padded_slots_stay_zero(self):
        h, g, bm, reg = self._spd(32, 10, seed=9)
        (x,) = model.cg_solve(h, g, bm, reg)
        np.testing.assert_allclose(np.asarray(x)[10:], 0.0, atol=1e-7)

    def test_matches_numpy_reference(self):
        h, g, bm, reg = self._spd(24, 24, seed=5)
        (x,) = model.cg_solve(h, g, bm, reg)
        expect = ref.cg_solve(h, g, bm, reg, iters=model.CG_MAX_ITERS)
        np.testing.assert_allclose(x, expect, rtol=1e-3, atol=1e-4)

    def test_identity_system(self):
        b = 16
        h = np.eye(b, dtype=np.float32)
        g = randn(b)
        bm = np.ones(b, np.float32)
        (x,) = model.cg_solve(h, g, bm, np.zeros(1, np.float32))
        np.testing.assert_allclose(x, g, rtol=1e-5, atol=1e-6)


class TestScoreAndPredict:
    def test_score_tile_matches_ref(self):
        kc, r = randn(256, 64), randn(256)
        a = (RNG.uniform(0, 1, 256) > 0.3).astype(np.float32)
        gc, hc = model.score_tile(kc, r * a, a)
        eg, eh = ref.score_tile(kc, r * a, a)
        np.testing.assert_allclose(gc, eg, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(hc, eh, rtol=1e-4, atol=1e-4)

    def test_predict_block_matches_ref(self):
        k, beta = randn(128, 32), randn(32)
        (f,) = model.predict_block(k, beta)
        np.testing.assert_allclose(f, ref.predict_block(k, beta),
                                   rtol=1e-4, atol=1e-5)

    def test_hc_nonnegative(self):
        kc = randn(128, 64)
        a = np.ones(128, np.float32)
        _, hc = model.score_tile(kc, randn(128), a)
        assert np.asarray(hc).min() >= -1e-5


class TestComposition:
    """Full SP-SVM Newton step stitched from the ops (as Rust will drive it)."""

    def test_newton_step_reduces_loss(self):
        rng = np.random.default_rng(3)
        t, d, nb = 256, 4, 33  # occupied basis 33 of 64 bucket
        b = 64
        x = rng.standard_normal((t, d)).astype(np.float32)
        y = np.sign(x[:, 0] * x[:, 1] + 0.1).astype(np.float32)
        xb = np.zeros((b, d), np.float32)
        xb[1:nb] = x[: nb - 1]  # slot 0 reserved for bias
        gamma = np.array([0.25], np.float32)
        c = np.array([1.0], np.float32)
        m = np.ones(t, np.float32)
        bm = np.zeros(b, np.float32)
        bm[:nb] = 1.0

        (k,) = model.kernel_block(x, xb, gamma)
        k = np.asarray(k).copy()
        k[:, 0] = 1.0  # bias column
        # K_JJ is computed on the Rust side (tiny, CPU); use the oracle here.
        kjj = np.asarray(ref.rbf_block(xb, xb, gamma)).copy()
        kjj[0, :] = 0.0
        kjj[:, 0] = 0.0  # bias unregularized

        def objective(beta):
            f = k @ beta
            hinge = np.maximum(0, 1 - y * f)
            return 0.5 * beta @ (kjj * np.outer(bm, bm)) @ beta + \
                float(c[0]) * np.sum(hinge ** 2)

        beta = np.zeros(b, np.float32)
        loss0 = objective(beta)
        for _ in range(3):
            g, h, _, _ = model.tile_stats(k, y, m, beta, c)
            g = np.asarray(g) + (kjj * np.outer(bm, bm)) @ beta
            h = np.asarray(h) + kjj
            (delta,) = model.cg_solve(h.astype(np.float32),
                                      (-g).astype(np.float32), bm,
                                      np.array([1e-4], np.float32))
            beta = beta + np.asarray(delta)
        loss1 = objective(beta)
        assert loss1 < 0.5 * loss0

    def test_tile_stats_c_factor_note(self):
        # tile_stats returns C/2-convention pieces scaled so that the
        # quadratic model is consistent: g uses 2C, H uses 2C, loss uses C.
        rng = np.random.default_rng(11)
        k = rng.uniform(0, 1, (128, 8)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], 128).astype(np.float32)
        m = np.ones(128, np.float32)
        beta = np.zeros(8, np.float32)
        g1, h1, l1, _ = model.tile_stats(k, y, m, beta,
                                         np.array([1.0], np.float32))
        g2, h2, l2, _ = model.tile_stats(k, y, m, beta,
                                         np.array([2.0], np.float32))
        np.testing.assert_allclose(np.asarray(g2), 2 * np.asarray(g1),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h2), 2 * np.asarray(h1),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(l2), 2 * np.asarray(l1),
                                   rtol=1e-5)
