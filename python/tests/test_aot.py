"""AOT lowering: every op lowers to custom-call-free HLO text."""

import os

import pytest

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("op", list(model.op_specs(256, 64, 64, 64)))
    def test_op_lowers_to_clean_hlo(self, op):
        text = aot.lower_op(op, 256, 64, 64, 64)
        assert "ENTRY" in text
        # xla_extension 0.5.1 cannot run jax-0.8 LAPACK/FFI custom-calls;
        # the artifact set must stay free of them.
        assert "custom-call" not in text, f"{op} emitted a custom-call"

    def test_plan_covers_all_ops(self):
        ops = {p[0] for p in aot.plan(aot.QUICK_D, aot.QUICK_B)}
        assert ops == set(model.op_specs(256, 64, 64, 64))

    def test_artifact_names_unique(self):
        p = aot.plan(aot.D_BUCKETS, aot.B_BUCKETS)
        names = [aot.artifact_name(*e) for e in p]
        assert len(names) == len(set(names))

    def test_quick_run_writes_manifest(self, tmp_path):
        import sys
        argv = sys.argv
        sys.argv = ["aot.py", "--out", str(tmp_path), "--quick"]
        try:
            assert aot.main() == 0
        finally:
            sys.argv = argv
        manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
        entries = [l for l in manifest if not l.startswith("#")]
        for line in entries:
            op, t, d, b, s, name = line.split()
            assert os.path.exists(tmp_path / name)
        assert len(entries) == len(aot.plan(aot.QUICK_D, aot.QUICK_B))
