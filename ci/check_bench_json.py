#!/usr/bin/env python3
"""Validate checked-in BENCH_*.json records against their embedded schema.

Every bench target emits a machine-readable JSON record whose "schema"
object documents its fields. A checked-in record is either a real
measurement (every schema key present) or an honest placeholder
("status": "not-run" with a "reason"). This gate runs before the smoke
pass so a malformed or silently-truncated record fails CI.

Usage: check_bench_json.py BENCH_a.json [BENCH_b.json ...]
"""
import json
import sys


def check(path: str) -> list:
    errors = []
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]
    schema = doc.get("schema")
    if not isinstance(schema, dict) or not schema:
        errors.append(f"{path}: missing embedded 'schema' object")
        return errors
    status = doc.get("status")
    if status == "not-run":
        if not doc.get("reason"):
            errors.append(f"{path}: not-run placeholder must carry a 'reason'")
    elif status is None:
        # a real measurement: every documented field must be present
        for key in schema:
            if key not in doc:
                errors.append(f"{path}: measurement is missing schema field '{key}'")
    else:
        errors.append(f"{path}: unknown status {status!r} (expected absent or 'not-run')")
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_bench_json.py BENCH_*.json", file=sys.stderr)
        return 2
    failures = []
    for path in argv:
        failures.extend(check(path))
    for msg in failures:
        print(f"error: {msg}", file=sys.stderr)
    if not failures:
        print(f"bench json ok: {len(argv)} file(s) validated")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
