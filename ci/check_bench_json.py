#!/usr/bin/env python3
"""Validate BENCH_*.json records: schema shape plus measured-ratio floors.

Every bench target emits a machine-readable JSON record whose "schema"
object documents its fields. A record is either a real measurement
(every schema key present) or an honest placeholder ("status":
"not-run" with a "reason"). On top of the shape check, measured records
are held to the performance floors the repo claims in its EXPERIMENTS
notes — a checked-in "measurement" that regressed below them fails CI:

  BENCH_gemm.json    speedup_vs_seed >= 2.0       (blocked GEMM vs seed dot-loop)
                     simd_microkernel.speedup >= 1.5   when backend != "scalar"
  BENCH_sparse.json  block_speedup >= 2.0         (CSR SpMM route vs densified, 90% sparsity)

Ratio floors are skipped for not-run placeholders (nothing was
measured), and backend-conditional floors are skipped when the record
says the process ran on the scalar backend — a scalar-only host can't
demonstrate a SIMD speedup and must not fake one. Measured records must
name their backend so the ratios are interpretable.

Measured records may also carry a "counters" object — the trace layer's
runtime-counter snapshot (TraceReport::counters_json). When present its
keys must come from the known counter set, values must be non-negative
integers, and the cache identity hits + misses == lookups must hold.

Usage: check_bench_json.py BENCH_a.json [BENCH_b.json ...]
"""
import json
import os
import sys

# rust/src/trace/counters.rs COUNTER_NAMES, kept in sync by the
# counters-section smoke in benchsmoke (an unknown key fails here)
COUNTER_NAMES = {
    "cache_lookups",
    "cache_hits",
    "cache_misses",
    "cache_evicted_bytes",
    "kernel_rows_computed",
    "pool_jobs",
    "pool_helper_joins",
    "gemm_flops",
    "gemm_bytes",
    "spmm_flops",
    "spmm_bytes",
    "engine_fallbacks",
    "events_dropped",
    "cascade_shards_trained",
    "cascade_svs_merged",
    "cascade_kkt_violations",
}

# basename -> list of (dotted field path, floor, needs_simd_backend)
RATIO_RULES = {
    "BENCH_gemm.json": [
        ("speedup_vs_seed", 2.0, False),
        ("simd_microkernel.speedup", 1.5, True),
    ],
    "BENCH_sparse.json": [
        ("block_speedup", 2.0, False),
    ],
}


def lookup(doc: dict, dotted: str):
    """Resolve a dotted path like 'simd_microkernel.speedup'; None if absent."""
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(path: str) -> list:
    errors = []
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]
    schema = doc.get("schema")
    if not isinstance(schema, dict) or not schema:
        errors.append(f"{path}: missing embedded 'schema' object")
        return errors
    status = doc.get("status")
    if status == "not-run":
        # honest placeholder: shape only, no ratios to hold it to
        if not doc.get("reason"):
            errors.append(f"{path}: not-run placeholder must carry a 'reason'")
        return errors
    if status is not None:
        errors.append(f"{path}: unknown status {status!r} (expected absent or 'not-run')")
        return errors

    # a real measurement: every documented field must be present
    for key in schema:
        if key not in doc:
            errors.append(f"{path}: measurement is missing schema field '{key}'")
    backend = doc.get("backend")
    if not isinstance(backend, str) or not backend:
        errors.append(f"{path}: measurement must name its 'backend' (scalar | avx2+fma | neon)")
        backend = "scalar"  # treat as scalar so only unconditional floors apply

    counters = doc.get("counters")
    if counters is not None:
        errors.extend(check_counters(path, counters))

    for dotted, floor, needs_simd in RATIO_RULES.get(os.path.basename(path), []):
        if needs_simd and backend == "scalar":
            print(f"note: {path}: {dotted} floor skipped (scalar backend)")
            continue
        value = lookup(doc, dotted)
        if not isinstance(value, (int, float)):
            errors.append(f"{path}: measurement is missing ratio field '{dotted}'")
        elif value < floor:
            errors.append(
                f"{path}: {dotted} = {value:.3f} is below the {floor:.2f}x floor "
                f"(backend {backend}) — performance regression or a broken fast path"
            )
    return errors


def check_counters(path: str, counters) -> list:
    """Validate an embedded trace-counter snapshot."""
    if not isinstance(counters, dict):
        return [f"{path}: 'counters' must be an object"]
    errors = []
    for key, value in counters.items():
        if key not in COUNTER_NAMES:
            errors.append(f"{path}: counters has unknown key '{key}'")
        if not isinstance(value, int) or isinstance(value, bool) or value < 0:
            errors.append(f"{path}: counters.{key} must be a non-negative integer")
    lookups = counters.get("cache_lookups")
    hits = counters.get("cache_hits")
    misses = counters.get("cache_misses")
    if all(isinstance(v, int) for v in (lookups, hits, misses)):
        if hits + misses != lookups:
            errors.append(
                f"{path}: counter identity broken: cache_hits ({hits}) + "
                f"cache_misses ({misses}) != cache_lookups ({lookups})"
            )
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_bench_json.py BENCH_*.json", file=sys.stderr)
        return 2
    failures = []
    for path in argv:
        failures.extend(check(path))
    for msg in failures:
        print(f"error: {msg}", file=sys.stderr)
    if not failures:
        print(f"bench json ok: {len(argv)} file(s) validated (schema + ratio floors)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
