#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file emitted by `--trace-json`.

The exporter (rust/src/trace/chrome.rs) walks each thread's span forest
depth-first, so a well-formed file satisfies checkable invariants beyond
"parses as JSON":

  * the document is a JSON array of event objects;
  * every event has ph/pid/tid/name, and B/E events a numeric ts;
  * per tid, the B/E stream is balanced: every E closes the most recent
    open B of the same name, and nothing stays open at the end;
  * per tid, timestamps are non-decreasing in stream order (depth-first
    emission of a nesting forest never goes backwards in time);
  * at least one duration event exists — an empty trace from a traced
    training run means the instrumentation fell off.

Usage: check_trace_json.py trace.json [more.json ...]
"""
import json
import sys


def check(path: str) -> list:
    try:
        with open(path, encoding="utf-8") as f:
            events = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]
    if not isinstance(events, list):
        return [f"{path}: top level must be a JSON array of trace events"]

    errors = []
    open_stacks = {}  # tid -> stack of open B names
    last_ts = {}  # tid -> last timestamp seen
    durations = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"{path}: event {i} is not an object")
            continue
        ph = ev.get("ph")
        tid = ev.get("tid")
        name = ev.get("name")
        if ph not in ("B", "E", "M"):
            errors.append(f"{path}: event {i} has unsupported ph {ph!r}")
            continue
        if ev.get("pid") != 1 or not isinstance(tid, int) or not isinstance(name, str):
            errors.append(f"{path}: event {i} is missing pid/tid/name")
            continue
        if ph == "M":
            continue
        durations += 1
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{path}: event {i} ({ph} {name}) has no numeric ts")
            continue
        if ts < last_ts.get(tid, 0.0):
            errors.append(
                f"{path}: event {i} ({ph} {name}) goes back in time on tid {tid} "
                f"({ts} < {last_ts[tid]})"
            )
        last_ts[tid] = ts
        stack = open_stacks.setdefault(tid, [])
        if ph == "B":
            stack.append(name)
        elif not stack:
            errors.append(f"{path}: event {i} closes '{name}' but tid {tid} has no open span")
        elif stack[-1] != name:
            errors.append(
                f"{path}: event {i} closes '{name}' but tid {tid}'s innermost open "
                f"span is '{stack[-1]}' (not properly nested)"
            )
        else:
            stack.pop()

    for tid, stack in open_stacks.items():
        if stack:
            errors.append(f"{path}: tid {tid} ends with unclosed span(s): {stack}")
    if durations == 0:
        errors.append(f"{path}: no B/E duration events at all — empty trace")
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_trace_json.py trace.json [more.json ...]", file=sys.stderr)
        return 2
    failures = []
    for path in argv:
        failures.extend(check(path))
    for msg in failures:
        print(f"error: {msg}", file=sys.stderr)
    if not failures:
        print(f"trace json ok: {len(argv)} file(s) validated (balanced, nested, ordered)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
