//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the real API this workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the
//! [`Context`] extension trait on `Result` and `Option`. Error values carry
//! a context chain of messages; `{}` shows the outermost message, `{:#}`
//! the full chain joined with ": ", and `{:?}` an anyhow-style "Caused by"
//! listing.

use std::fmt;

/// Error type: an outermost message plus the chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) message.
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an additional outer context message.
    pub fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Context-attaching extension for `Result` and `Option` (the real
/// anyhow's `Context` trait, minus the sealing).
pub trait Context<T, E> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Attach a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Result::<(), _>::Err(io_err()).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert_eq!(inner(false).unwrap_err().to_string(), "flag was false");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("5").unwrap(), 5);
        assert!(parse("x").is_err());
    }
}
