//! API stub for the PJRT/XLA binding used by `wu_svm::runtime`.
//!
//! The offline build container has no XLA/PJRT shared libraries, so
//! [`PjRtClient::cpu`] always returns an "xla backend unavailable" error.
//! `XlaRuntime::load` therefore fails cleanly and every caller falls back
//! to the cpu engines (all xla tests and benches skip when the runtime is
//! absent). The remaining types exist so the hot-path code type-checks
//! exactly as it would against the real binding; their methods are
//! unreachable because no client can ever be constructed.

#![allow(dead_code)]

use std::path::Path;

/// Stub error; `Debug`-formatted at every call site.
#[derive(Debug)]
pub struct Error(pub String);

const UNAVAILABLE: &str =
    "xla backend unavailable: this build uses the offline API stub (see vendor/README.md)";

/// PJRT client handle. Construction always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

/// Host-side literal value.
pub struct Literal {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    /// Copy a host buffer to the device.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

impl PjRtLoadedExecutable {
    /// Execute with explicit device buffers.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

impl PjRtBuffer {
    /// Fetch the buffer back to the host.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

impl Literal {
    /// Split a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unreachable!("stub Literal cannot be constructed")
    }

    /// Flatten to a host vector.
    pub fn to_vec<T>(self) -> Result<Vec<T>, Error> {
        unreachable!("stub Literal cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("unavailable"));
    }
}
