//! Minimal offline stand-in for the `once_cell` crate: `sync::OnceCell`
//! as a thin wrapper over `std::sync::OnceLock`.

pub mod sync {
    /// Thread-safe cell that can be written to at most once.
    #[derive(Debug, Default)]
    pub struct OnceCell<T> {
        inner: std::sync::OnceLock<T>,
    }

    impl<T> OnceCell<T> {
        /// Create an empty cell (usable in `static` initializers).
        pub const fn new() -> OnceCell<T> {
            OnceCell { inner: std::sync::OnceLock::new() }
        }

        /// The stored value, if set.
        pub fn get(&self) -> Option<&T> {
            self.inner.get()
        }

        /// Store a value; returns it back if the cell was already set.
        pub fn set(&self, value: T) -> Result<(), T> {
            self.inner.set(value)
        }

        /// Get the stored value, initializing it with `f` if empty.
        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.inner.get_or_init(f)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::OnceCell;

    static CELL: OnceCell<u32> = OnceCell::new();

    #[test]
    fn set_once_then_read() {
        assert!(CELL.get().is_none() || CELL.get() == Some(&42));
        let _ = CELL.set(42);
        assert_eq!(CELL.get(), Some(&42));
        assert_eq!(CELL.set(7), Err(7));
        assert_eq!(*CELL.get_or_init(|| 9), 42);
    }
}
